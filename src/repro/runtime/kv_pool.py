"""Paged KV cache pool: refcounted host-side block accounting for serving.

The device-side layout is a shared pool of ``num_blocks`` fixed-size KV
blocks per layer (:func:`repro.models.init_paged_cache`); this module owns
the *accounting*: which physical blocks are free, which belong to which
request, how many owners a block has, and whether admission head-room
exists.  It is pure host Python — no jax — so its invariants (no leaks, no
double allocation, refcounts never negative, deterministic order) are
testable under heavy churn without touching a device.

Design points (the vLLM block-manager shape, reduced to essentials):

* **fixed-size blocks** — every block covers ``page_size`` consecutive
  logical token positions of one sequence; a request holding ``n`` tokens
  owns ``ceil(n / page_size)`` blocks, listed in logical order in its
  *block table*.
* **refcounted sharing** — a physical block may appear in several block
  tables at once (prefix sharing) and additionally be pinned by the prefix
  index below.  ``alloc`` hands out blocks at refcount 1; ``incref`` adds
  owners; ``free`` decrements and only a block reaching refcount 0 returns
  to the free list.  A block with refcount > 1 is *shared*: writers must
  copy-on-write (the scheduler plans the copy, the engine executes it
  device-side) before mutating it.
* **prefix index** — a trie over chain-hashes of ``page_size``-aligned
  token blocks (``h_i = hash((h_{i-1}, tokens_i))``) maps full prompt
  blocks to the physical block already holding their KV.  A new request
  whose prompt shares a prefix with a live or recently-retired sequence
  maps those blocks instead of re-prefilling them; the index holds one
  refcount per cached block, so retirement leaves registered blocks
  resident ("recently retired") until the allocator reclaims them LRU
  when the free list runs dry.
* **free-list allocation** — allocation pops from a free stack
  (deterministic: a fresh pool hands out blocks 1, 2, 3, …; freed blocks
  are reused most-recently-freed first).  ``alloc`` is all-or-nothing and
  reclaims idle cached prefix blocks before refusing.
* **copy-free retirement** — finishing (or preempting) a request decrefs
  its blocks; nothing on the device moves.  Stale KV in a reused block is
  overwritten position-by-position by its next owner and is causally
  masked until then.
* **reserved garbage block 0** — never allocated, never refcounted; dead
  decode-batch rows point their whole block table at it so the batched
  decode step has a harmless write target.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..obs import recorder as obs
from ..obs.events import PrefixHit
from . import faults

GARBAGE_BLOCK = 0

#: chain-hash seed for "no blocks yet" (position 0 of every sequence).
PREFIX_ROOT = 0


@dataclass
class PoolStats:
    allocs: int = 0                  # successful alloc() calls
    frees: int = 0                   # free() calls
    blocks_allocated: int = 0        # cumulative blocks handed out
    blocks_freed: int = 0            # cumulative blocks returned (refs -> 0)
    alloc_failures: int = 0          # all-or-nothing refusals
    peak_live: int = 0               # high-water mark of live blocks
    prefix_hits: int = 0             # blocks mapped from the prefix index
    prefix_tokens_saved: int = 0     # token positions served from the index
    prefix_misses: int = 0           # match_prefix calls that mapped nothing
    cow_copies: int = 0              # shared blocks duplicated before a write
    cache_evictions: int = 0         # idle cached blocks reclaimed by alloc


@dataclass
class _PrefixEntry:
    """One cached full block: its physical id, exact token content (for
    partial-tail matching), and its parent chain hash (for child cleanup)."""

    block: int
    tokens: Tuple[int, ...]
    prev: int


@dataclass
class PagedKVPool:
    """Refcounted free-list allocator over ``num_blocks`` physical blocks.

    ``num_blocks`` counts physical blocks *including* the reserved garbage
    block 0, matching the leading pool axis of the device cache leaves.
    """

    num_blocks: int
    page_size: int
    stats: PoolStats = field(default_factory=PoolStats)

    def __post_init__(self) -> None:
        if self.num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is reserved)")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        # stack: pop() yields 1, 2, 3, ... on a fresh pool
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._refs: Dict[int, int] = {}
        # prefix index: chain hash -> entry, LRU-ordered (oldest first);
        # _children[prev_hash] lists child hashes for partial-tail matching
        self._index: "collections.OrderedDict[int, _PrefixEntry]" = \
            collections.OrderedDict()
        self._children: Dict[int, List[int]] = {}

    # -- sizing ---------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the garbage block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return len(self._refs)

    @property
    def _live(self) -> set:
        """Live block set (compat view over the refcount table)."""
        return set(self._refs)

    @property
    def num_reclaimable(self) -> int:
        """Cached prefix blocks held only by the index (refcount 1): the
        allocator can reclaim these, so admission head-room counts them as
        free-in-waiting."""
        return sum(1 for e in self._index.values()
                   if self._refs.get(e.block, 0) == 1)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` logical positions."""
        return -(-max(int(tokens), 0) // self.page_size)

    def ref(self, block: int) -> int:
        return self._refs.get(block, 0)

    def is_shared(self, block: int) -> bool:
        """More than one owner (block tables + prefix index): a write must
        copy-on-write first."""
        return self._refs.get(block, 0) > 1

    # -- alloc / free ---------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks at refcount 1, or ``None`` (and nothing changes)
        if the pool cannot satisfy the whole request — callers never hold a
        partial grant they would have to unwind.  Reclaims idle cached
        prefix blocks (LRU) before refusing."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        spec = faults.maybe_fault("pool.alloc")
        if spec is not None and spec.kind == "exhaust":
            # injected burst pressure: refuse exactly like a real shortfall
            # (the scheduler's head-room/preemption machinery must absorb it)
            self.stats.alloc_failures += 1
            return None
        if n > len(self._free) + self.num_reclaimable:
            self.stats.alloc_failures += 1
            return None
        while len(self._free) < n:
            self._evict_one_cached()
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._refs[b] = 1
        self.stats.allocs += 1
        self.stats.blocks_allocated += n
        self.stats.peak_live = max(self.stats.peak_live, len(self._refs))
        return got

    def incref(self, blocks: Iterable[int]) -> None:
        """Add an owner to already-live blocks (prefix mapping, index pin)."""
        for b in blocks:
            if b not in self._refs:
                raise ValueError(f"incref of non-live block {b}")
            self._refs[b] += 1

    def free(self, blocks: List[int]) -> None:
        """Drop one owner per block; blocks reaching refcount 0 return to
        the free list.  Decrefs below zero (double-frees) and frees of the
        garbage block are accounting bugs and raise immediately."""
        for b in blocks:
            r = self._refs.get(b)
            if r is None:
                raise ValueError(f"free of non-live block {b}")
            if r > 1:
                self._refs[b] = r - 1
            else:
                del self._refs[b]
                self._free.append(b)
                self.stats.blocks_freed += 1
        self.stats.frees += 1

    # -- prefix index ---------------------------------------------------------
    @staticmethod
    def chain_hash(prev: int, tokens: Tuple[int, ...]) -> int:
        """Deterministic-in-process chain hash of one full token block
        (int tuples hash value-stably; no str/bytes randomization)."""
        return hash((prev, tokens))

    def register_prefix(self, prev_hash: int, tokens: Sequence[int],
                        block: int) -> int:
        """Index one *full* block of prompt content under its chain hash.

        The index takes a refcount on the block (it stays resident after
        its owner retires) unless the hash is already mapped — first
        registration wins, so identical content always resolves to one
        physical block.  Returns the chain hash (feed it to the next
        ``register_prefix`` call as ``prev_hash``)."""
        toks = tuple(int(t) for t in tokens)
        if len(toks) != self.page_size:
            raise ValueError(
                f"register_prefix needs a full block of {self.page_size} "
                f"tokens, got {len(toks)}")
        h = self.chain_hash(prev_hash, toks)
        if h in self._index:
            self._index.move_to_end(h)
            return h
        if block not in self._refs:
            raise ValueError(f"register_prefix of non-live block {block}")
        self._refs[block] += 1            # the index's own pin
        self._index[h] = _PrefixEntry(block=block, tokens=toks,
                                      prev=prev_hash)
        self._children.setdefault(prev_hash, []).append(h)
        return h

    def match_prefix(self, tokens: Sequence[int], *, commit: bool = True
                     ) -> Tuple[List[int], int, int]:
        """Longest indexed prefix of ``tokens``: full chain-hash blocks,
        then a partial overlap into one child block (CoW territory — the
        mapper's first write into it duplicates the block).

        Returns ``(blocks, matched, chain_hash)``: the physical blocks to
        map (in logical order), how many leading tokens they serve, and the
        chain hash covering the *full* matched blocks (so the caller
        continues registering from there).  ``matched`` is capped at
        ``len(tokens) - 1`` — at least one token always prefills, because
        its logits must seed decode.  ``commit=False`` probes without
        increfing or touching LRU order (admission head-room checks)."""
        toks = [int(t) for t in tokens]
        ps, n = self.page_size, len(toks)
        hashes = [PREFIX_ROOT]
        blocks: List[int] = []
        i = 0
        while (i + 1) * ps <= n:
            h = self.chain_hash(hashes[-1], tuple(toks[i * ps:(i + 1) * ps]))
            ent = self._index.get(h)
            if ent is None:
                break
            blocks.append(ent.block)
            hashes.append(h)
            i += 1
        matched = i * ps
        # partial tail: best token-overlap among the children of the chain
        # head (deterministic: max overlap, first-registered wins ties)
        rem = toks[matched:]
        best_overlap, best_block = 0, None
        if rem:
            for ch in self._children.get(hashes[-1], ()):
                ent = self._index.get(ch)
                if ent is None:
                    continue
                k = 0
                for a, b in zip(ent.tokens, rem):
                    if a != b:
                        break
                    k += 1
                if k > best_overlap:
                    best_overlap, best_block = k, ent.block
        if best_block is not None:
            blocks.append(best_block)
            matched += best_overlap
        if matched >= n:                 # leave >= 1 token to prefill
            matched = n - 1
            blocks = blocks[:self.blocks_for(matched)]
            hashes = hashes[:matched // ps + 1]
        if not blocks:
            if commit:
                self.stats.prefix_misses += 1
            return [], 0, PREFIX_ROOT
        if commit:
            self.incref(blocks)
            for h in hashes[1:]:
                self._index.move_to_end(h)
            self.stats.prefix_hits += len(blocks)
            self.stats.prefix_tokens_saved += matched
            if obs._recorder is not None:     # pool has no tick: use cursor
                obs._recorder.emit(PrefixHit(tick=obs._recorder.tick,
                                             blocks=len(blocks),
                                             tokens=int(matched)))
        return blocks, matched, hashes[min(len(hashes) - 1, matched // ps)]

    def release_prefix_cache(self) -> int:
        """Drop every index entry (decref its pin); blocks still mapped by
        live sequences survive, idle ones return to the free list.  Returns
        the number of entries dropped (tests and benchmarks use this to
        compare against a cold cache)."""
        dropped = 0
        for h in list(self._index):
            self._drop_entry(h)
            dropped += 1
        return dropped

    def _drop_entry(self, h: int) -> None:
        ent = self._index.pop(h)
        kids = self._children.get(ent.prev)
        if kids is not None:
            kids.remove(h)
            if not kids:
                del self._children[ent.prev]
        self.free([ent.block])           # drop the index's pin

    def _evict_one_cached(self) -> None:
        """Reclaim the LRU-oldest cached block nobody maps (refcount 1 =
        index pin only).  Callers guarantee one exists."""
        for h, ent in self._index.items():
            if self._refs.get(ent.block, 0) == 1:
                self._drop_entry(h)
                self.stats.cache_evictions += 1
                return
        raise AssertionError("evict called with no reclaimable cached block")

    # -- invariants -----------------------------------------------------------
    def check_invariants(self, block_tables: Optional[
            Iterable[Sequence[int]]] = None) -> None:
        """Raise if accounting broke: every block is exactly free or live
        (refcount >= 1), block 0 is neither, nothing was minted or lost,
        every indexed block is alive, and — when the caller passes the
        sequences' ``block_tables`` — every table entry is live, disjoint
        from the free list, and its refcount covers its mappers."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate entries in the free list")
        if free & set(self._refs):
            raise AssertionError("block both free and live")
        if GARBAGE_BLOCK in free or GARBAGE_BLOCK in self._refs:
            raise AssertionError("garbage block 0 entered circulation")
        if any(r < 1 for r in self._refs.values()):
            raise AssertionError("non-positive refcount on a live block")
        if len(free) + len(self._refs) != self.capacity:
            raise AssertionError(
                f"leak: {len(free)} free + {len(self._refs)} live != "
                f"{self.capacity} capacity")
        owners: Dict[int, int] = {}
        for ent in self._index.values():
            if ent.block not in self._refs:
                raise AssertionError(
                    f"indexed block {ent.block} is not live")
            owners[ent.block] = owners.get(ent.block, 0) + 1
        if block_tables is not None:
            for table in block_tables:
                for b in table:
                    if b in free:
                        raise AssertionError(
                            f"block {b} is in a block table AND the free "
                            f"list")
                    if b not in self._refs:
                        raise AssertionError(
                            f"block-table block {b} is not live")
                    owners[b] = owners.get(b, 0) + 1
            for b, n in owners.items():
                if self._refs[b] < n:
                    raise AssertionError(
                        f"block {b}: {n} owners but refcount "
                        f"{self._refs[b]}")
