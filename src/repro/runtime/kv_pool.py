"""Paged KV cache pool: host-side block accounting for the serving engine.

The device-side layout is a shared pool of ``num_blocks`` fixed-size KV
blocks per layer (:func:`repro.models.init_paged_cache`); this module owns
the *accounting*: which physical blocks are free, which belong to which
request, and whether admission head-room exists.  It is pure host Python —
no jax — so its invariants (no leaks, no double allocation, deterministic
order) are testable under heavy churn without touching a device.

Design points (the vLLM block-manager shape, reduced to essentials):

* **fixed-size blocks** — every block covers ``page_size`` consecutive
  logical token positions of one sequence; a request holding ``n`` tokens
  owns ``ceil(n / page_size)`` blocks, listed in logical order in its
  *block table*.
* **free-list allocation** — allocation pops from a free stack
  (deterministic: a fresh pool hands out blocks 1, 2, 3, …; freed blocks
  are reused most-recently-freed first).  ``alloc`` is all-or-nothing.
* **copy-free retirement** — finishing (or preempting) a request returns
  its blocks to the free list; nothing on the device moves.  Stale KV in a
  reused block is overwritten position-by-position by its next owner and
  is causally masked until then.
* **reserved garbage block 0** — never allocated; dead decode-batch rows
  point their whole block table at it so the batched decode step has a
  harmless write target.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

GARBAGE_BLOCK = 0


@dataclass
class PoolStats:
    allocs: int = 0                  # successful alloc() calls
    frees: int = 0                   # free() calls
    blocks_allocated: int = 0        # cumulative blocks handed out
    blocks_freed: int = 0            # cumulative blocks returned
    alloc_failures: int = 0          # all-or-nothing refusals
    peak_live: int = 0               # high-water mark of live blocks


@dataclass
class PagedKVPool:
    """Free-list allocator over ``num_blocks`` physical KV blocks.

    ``num_blocks`` counts physical blocks *including* the reserved garbage
    block 0, matching the leading pool axis of the device cache leaves.
    """

    num_blocks: int
    page_size: int
    stats: PoolStats = field(default_factory=PoolStats)

    def __post_init__(self) -> None:
        if self.num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is reserved)")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        # stack: pop() yields 1, 2, 3, ... on a fresh pool
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._live: set = set()

    # -- sizing ---------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the garbage block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return len(self._live)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` logical positions."""
        return -(-max(int(tokens), 0) // self.page_size)

    # -- alloc / free ---------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks, or ``None`` (and nothing changes) if the pool
        cannot satisfy the whole request — callers never hold a partial
        grant they would have to unwind."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            self.stats.alloc_failures += 1
            return None
        got = [self._free.pop() for _ in range(n)]
        self._live.update(got)
        self.stats.allocs += 1
        self.stats.blocks_allocated += n
        self.stats.peak_live = max(self.stats.peak_live, len(self._live))
        return got

    def free(self, blocks: List[int]) -> None:
        """Return blocks to the free list.  Double-frees and frees of the
        garbage block are accounting bugs and raise immediately."""
        for b in blocks:
            if b not in self._live:
                raise ValueError(f"free of non-live block {b}")
            self._live.discard(b)
            self._free.append(b)
        self.stats.frees += 1
        self.stats.blocks_freed += len(blocks)

    # -- invariants -----------------------------------------------------------
    def check_invariants(self) -> None:
        """Raise if accounting broke: every block is exactly free or live,
        block 0 is neither, and nothing was minted or lost."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate entries in the free list")
        if free & self._live:
            raise AssertionError("block both free and live")
        if GARBAGE_BLOCK in free or GARBAGE_BLOCK in self._live:
            raise AssertionError("garbage block 0 entered circulation")
        if len(free) + len(self._live) != self.capacity:
            raise AssertionError(
                f"leak: {len(free)} free + {len(self._live)} live != "
                f"{self.capacity} capacity")
