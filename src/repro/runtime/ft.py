"""Fault tolerance: restart loop, straggler detection, elastic re-mesh.

At thousand-node scale the failure model is: hosts vanish (preemption,
hardware), hosts slow down (thermal, network), and the job must make
progress anyway.  Three mechanisms:

* :class:`TrainController` — the restartable outer loop.  Checkpoint every
  ``ckpt_every`` steps (async).  Any step that raises is retried from the
  latest valid checkpoint; the data pipeline is stateless (`batch_at(step)`)
  so the replay is exact.  An injectable ``fault_hook`` lets tests (and
  chaos drills) kill arbitrary steps.
* :class:`StragglerMonitor` — EWMA + percentile step-time tracker.  A host
  whose step time exceeds ``factor``× the rolling median is flagged;
  the controller logs it and (in a real deployment) the scheduler would
  swap the host.  Detection logic is pure and unit-tested.
* :func:`elastic_mesh_shape` — re-derive the (data, model) mesh from a
  surviving device count.  Model-parallel degree is kept if possible
  (weights reshard cheaply along data), else reduced to the largest
  divisor; training resumes from the checkpoint with the new mesh — the
  checkpoint format is sharding-agnostic (host-gathered numpy leaves).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.ft")

PyTree = Any


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------

@dataclass
class StragglerMonitor:
    factor: float = 2.0          # flag hosts slower than factor x median
    window: int = 64             # rolling window of step times per host
    min_samples: int = 8
    _times: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, host: int, seconds: float) -> None:
        buf = self._times.setdefault(host, [])
        buf.append(seconds)
        if len(buf) > self.window:
            del buf[0]

    def medians(self) -> Dict[int, float]:
        return {h: float(np.median(v)) for h, v in self._times.items() if v}

    def stragglers(self) -> List[int]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        counts = {h: len(self._times[h]) for h in meds}
        global_med = float(np.median(list(meds.values())))
        return [h for h, m in meds.items()
                if counts[h] >= self.min_samples and
                m > self.factor * global_med]


# ---------------------------------------------------------------------------
# Elastic mesh policy
# ---------------------------------------------------------------------------

def elastic_mesh_shape(n_devices: int, *, prefer_model: int = 16,
                       ) -> Tuple[int, int]:
    """(data, model) for a surviving device count.

    Keeps model-parallel degree at ``prefer_model`` when divisible (weights
    need no resharding along the model axis), else the largest divisor —
    training always restarts with *some* valid mesh as long as one device
    survives.
    """
    if n_devices <= 0:
        raise ValueError("no surviving devices")
    model = prefer_model
    while model > 1 and n_devices % model != 0:
        model //= 2
    return n_devices // model, model


# ---------------------------------------------------------------------------
# Restartable training controller
# ---------------------------------------------------------------------------

@dataclass
class TrainController:
    """Checkpoint/restart training loop with fault injection hooks.

    ``run_step(state, step) -> (state, metrics)`` is the jitted train step
    already closed over the mesh; ``state`` is any pytree (params +
    opt_state).  ``next_batch(step)`` is the stateless data address.
    """

    run_step: Callable[[PyTree, int], Tuple[PyTree, Dict[str, float]]]
    ckpt: Any                                 # CheckpointManager
    ckpt_every: int = 50
    max_retries: int = 3
    fault_hook: Optional[Callable[[int], None]] = None   # raises to inject
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    host_index: int = 0

    def run(self, state: PyTree, *, start_step: int, num_steps: int
            ) -> Tuple[PyTree, List[Dict[str, float]]]:
        history: List[Dict[str, float]] = []
        initial = state            # pre-first-checkpoint restarts replay this
        step = start_step
        retries = 0
        while step < start_step + num_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                t0 = time.perf_counter()
                state, metrics = self.run_step(state, step)
                dt = time.perf_counter() - t0
                self.monitor.record(self.host_index, dt)
                metrics = dict(metrics)
                metrics["step"] = step
                metrics["step_time_s"] = dt
                history.append(metrics)
                step += 1
                retries = 0
                if step % self.ckpt_every == 0:
                    self.ckpt.save_async(step, state)
                slow = self.monitor.stragglers()
                if slow:
                    log.warning("stragglers detected: hosts %s", slow)
            except KeyboardInterrupt:
                raise
            except Exception as e:           # noqa: BLE001 — restart path
                retries += 1
                if retries > self.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring latest "
                            "checkpoint (retry %d/%d)", step, e, retries,
                            self.max_retries)
                restored_step, restored = self.ckpt.restore_latest(state)
                if restored is None:
                    # no checkpoint yet: restart from the initial state —
                    # rewinding the step counter alone would re-apply
                    # updates already folded into the live state
                    step = start_step
                    state = initial
                else:
                    state = restored
                    step = restored_step
        self.ckpt.save(step, state)
        return state, history
