"""train_step / serve_step builders (the functions the launcher jits).

``build_train_step`` assembles: microbatched gradient accumulation
(lax.scan, so per-device activation memory is one microbatch), f32 (or
bf16, for the 1T MoE) accumulators sharded like the parameters
(=> GSPMD reduce-scatters each microbatch's grads: ZeRO-2), global-norm
clipping, the MoE auxiliary loss, z-loss, and the optimizer update.

``build_serve_steps`` returns (prefill_step, decode_step) closures over the
config; decode donates the cache so serving is allocation-free per token.

Everything here is mesh-agnostic: shardings are applied by the launcher via
in_shardings/out_shardings; the bodies only use ``constrain`` hints.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import forward, decode_step as model_decode, prefill as model_prefill
from ..models.config import ModelConfig
from ..optim import Optimizer, clip_by_global_norm

PyTree = Any

MOE_AUX_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


def cross_entropy(logits: jax.Array, labels: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Mean token NLL (+ z-loss term), f32 accumulation.

    Returns (nll, z_loss).  The z-loss (log^2 Z) keeps the softmax
    normalizer bounded on long runs — standard large-scale practice.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    z = jnp.mean(jnp.square(logz))
    return nll, z


def _batch_extras(cfg: ModelConfig, batch: Dict[str, jax.Array]) -> Dict:
    kw = {}
    if cfg.encoder is not None:
        kw["enc_embeds"] = batch["enc_embeds"]
    elif cfg.frontend == "stub" and "patch_embeds" in batch:
        kw["patch_embeds"] = batch["patch_embeds"]
    return kw


def loss_fn(params: PyTree, cfg: ModelConfig, batch: Dict[str, jax.Array],
            unroll: bool = False) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch["tokens"], unroll=unroll,
                          **_batch_extras(cfg, batch))
    nll, z = cross_entropy(logits, batch["labels"])
    loss = nll + MOE_AUX_WEIGHT * aux + Z_LOSS_WEIGHT * z
    return loss, {"nll": nll, "moe_aux": aux, "z": z}


def build_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                     microbatches: int = 1,
                     clip_norm: float = 1.0,
                     grad_dtype=jnp.float32,
                     unroll: bool = False,
                     acc_shardings=None,
                     ) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    ``acc_shardings``: optional NamedSharding tree for the gradient
    accumulators.  Constraining them to the ZeRO-1 (batch-axes-extended)
    layout turns the per-microbatch gradient all-reduce into a
    reduce-scatter — half the bytes on the wire (ZeRO-2)."""

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, jax.Array], step: jax.Array):
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = B // microbatches

        def reshape_mb(x):
            return x.reshape((microbatches, mb) + x.shape[1:])

        mbatches = jax.tree.map(reshape_mb, batch)
        grad_of = jax.value_and_grad(
            functools.partial(loss_fn, cfg=cfg, unroll=unroll), has_aux=True)

        def micro(carry, mbatch):
            acc, loss_sum, nll_sum, aux_sum = carry
            (loss, metr), grads = grad_of(params, batch=mbatch)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(grad_dtype), acc, grads)
            return (acc, loss_sum + loss, nll_sum + metr["nll"],
                    aux_sum + metr["moe_aux"]), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, grad_dtype), params)
        if acc_shardings is not None:
            zeros = jax.tree.map(
                jax.lax.with_sharding_constraint, zeros, acc_shardings)
        init = (zeros, jnp.zeros((), jnp.float32),
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        (gacc, loss_sum, nll_sum, aux_sum), _ = jax.lax.scan(
            micro, init, mbatches)

        grads = jax.tree.map(lambda g: g / microbatches, gacc)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        metrics = {
            "loss": loss_sum / microbatches,
            "nll": nll_sum / microbatches,
            "moe_aux": aux_sum / microbatches,
            "grad_norm": gnorm,
        }
        return new_params, new_opt, metrics

    return train_step


def build_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss, metr = loss_fn(params, cfg, batch)
        return {"loss": loss, **metr}
    return eval_step


def build_serve_steps(cfg: ModelConfig, *, unroll: bool = False
                      ) -> Tuple[Callable, Callable]:
    """(prefill_step, decode_step) for the serving engine.

    prefill_step(params, tokens, cache[, enc_embeds/patch_embeds])
        -> (last_logits, cache)
    decode_step(params, tokens(B,1), cache, index) -> (logits, cache)
    """

    def prefill_step(params, tokens, cache, **kw):
        return model_prefill(params, cfg, tokens, cache, unroll=unroll, **kw)

    def decode_one(params, tokens, cache, index):
        return model_decode(params, cfg, tokens, cache, index, unroll=unroll)

    return prefill_step, decode_one


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
