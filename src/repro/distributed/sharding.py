"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates every parameter dimension with a *logical* axis name
("embed", "ff", "vocab", "expert", ...).  This module maps logical names to
mesh axes per architecture:

* **TP** ("model" axis): attention head projections, MLP hidden, vocab.
* **EP** ("data" axis): MoE expert dim — each data shard owns E/16 experts
  and GSPMD emits the dispatch/combine all-to-all between the token-sharded
  and expert-sharded layouts.
* **FSDP** (("pod","data")): the `embed` dim of weight matrices for the
  archs whose parameters cannot live TP-only (kimi-k2 1T, llama4-scout,
  chameleon-34b).  With scan-over-layers this yields the per-layer
  all-gather / reduce-scatter schedule of ZeRO-3.
* **ZeRO-1** optimizer extension: optimizer-state (and gradient-accumulator)
  leaves additionally shard their largest still-replicated divisible dim
  over ("pod","data").

The same rules drive: parameter shardings, optimizer-state shardings, input
batch specs, KV-cache specs, and the ``constrain`` hints inside model code.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

PyTree = Any
MeshAxes = Union[None, str, Tuple[str, ...]]

# archs whose parameter memory requires FSDP over the batch axes
FSDP_ARCHS = ("kimi-k2-1t-a32b", "llama4-scout-17b-a16e", "chameleon-34b")


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rules_for(cfg: ModelConfig, mesh: Mesh) -> Dict[str, MeshAxes]:
    """Logical-axis -> mesh-axes mapping for this arch on this mesh."""
    batch = batch_axes(mesh)
    fsdp = cfg.name in FSDP_ARCHS
    rules: Dict[str, MeshAxes] = {
        "layers": None,
        "embed": batch if fsdp else None,
        "q_proj": "model",
        "kv_proj": "model",
        "heads": "model",
        "kv_heads": "model",
        "kv_hd": "model",      # cache head_dim fallback ('kv_cache_hd' flag)
        "ff": "model",
        "vocab": "model",
        "ssm_inner": "model",
        "ssm_bc": "model",
        "ssm_heads": "model",
        # MoE: EP over the data axis; expert-ff TP over model.  With the
        # 'moe_2d_ep' flag (or 'moe_a2a' with padded storage), experts
        # shard over (data x model): the expert FFN is fully local and the
        # shard_map all-to-all consumes weights without resharding
        # (§Perf iters B4/B6).
        "expert": (("data", "model")
                   if ("moe_2d_ep" in cfg.perf_flags
                       or ("moe_a2a" in cfg.perf_flags and cfg.moe
                           and cfg.moe.num_experts >= 256))
                   and "data" in mesh.axis_names
                   else "data" if "data" in mesh.axis_names else None),
        "moe_dmodel": "model",   # dispatched-tensor d_model (RS not AR)
        # activations
        "batch": batch,
        "moe_groups": batch,
        "seq": None,
    }
    return rules


def spec_for(axes: Sequence[Optional[str]], rules: Mapping[str, MeshAxes],
             shape: Optional[Tuple[int, ...]] = None) -> P:
    """PartitionSpec from logical axes.

    Two safety rails, both revisited during perf hillclimbs (DESIGN.md §6):
    * non-divisible dims fall back to replicated (GSPMD would pad — wasted
      memory and bandwidth);
    * a mesh axis is given to at most one dim, left-to-right (e.g. kimi's
      expert tensors ask for 'data' via both EP and FSDP; EP wins and the
      FSDP entry keeps only its unused axes).
    """
    entries = []
    used: set = set()
    mesh = current_mesh()
    for i, ax in enumerate(axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            entries.append(None)
            continue
        axes_tuple = (m,) if isinstance(m, str) else tuple(m)
        axes_tuple = tuple(a for a in axes_tuple if a not in used)
        if not axes_tuple:
            entries.append(None)
            continue
        if shape is not None and mesh is not None:
            prod = int(np.prod([mesh.shape[a] for a in axes_tuple]))
            if shape[i] % prod != 0:
                entries.append(None)
                continue
        used.update(axes_tuple)
        entries.append(axes_tuple if len(axes_tuple) > 1 else axes_tuple[0])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for(axes_tree: PyTree, params_tree: PyTree, mesh: Mesh,
                  rules: Mapping[str, MeshAxes]) -> PyTree:
    """NamedSharding tree matching ``params_tree`` from logical axes."""
    def one(axes, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        return NamedSharding(mesh, spec_for(tuple(axes), rules, shape))
    return jax.tree.map(one, axes_tree, params_tree,
                        is_leaf=lambda t: isinstance(t, tuple))


def zero1_shardings(param_shardings: PyTree, params_tree: PyTree, mesh: Mesh
                    ) -> PyTree:
    """Optimizer-state sharding: param sharding + extra batch-axes shard.

    For each leaf, shard the largest still-replicated dim divisible by the
    batch axes over ("pod","data") — classic ZeRO-1 partitioning expressed
    as GSPMD shardings (the reduce-scatter/all-gather pair appears in the
    lowered collective schedule).
    """
    batch = batch_axes(mesh)
    if not batch:
        return param_shardings
    denom = int(np.prod([mesh.shape[a] for a in batch]))

    def one(sh: NamedSharding, leaf):
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        # pick the largest replicated divisible dim
        best, best_size = None, 0
        for i, (entry, size) in enumerate(zip(spec, leaf.shape)):
            if entry is None and size % denom == 0 and size > best_size:
                best, best_size = i, size
        if best is not None:
            spec[best] = batch if len(batch) > 1 else batch[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, param_shardings, params_tree,
                        is_leaf=lambda t: isinstance(t, NamedSharding))


# ---------------------------------------------------------------------------
# Current-mesh registry (used by `constrain` inside model code)
# ---------------------------------------------------------------------------

_CURRENT: Dict[str, Any] = {"mesh": None, "rules": None}


class use_mesh_rules:
    """Context manager installing (mesh, rules) for ``constrain`` calls."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Mapping] = None):
        self.mesh, self.rules = mesh, rules
        self._saved = None

    def __enter__(self):
        self._saved = dict(_CURRENT)
        _CURRENT["mesh"] = self.mesh
        _CURRENT["rules"] = self.rules
        return self

    def __exit__(self, *exc):
        _CURRENT.update(self._saved)
        return False


def current_mesh() -> Optional[Mesh]:
    return _CURRENT["mesh"]


def current_rules() -> Optional[Mapping[str, MeshAxes]]:
    return _CURRENT["rules"]


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]
              ) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh.

    Model code calls this at block boundaries so CPU tests run unchanged
    while the 512-chip lowering gets anchored activation layouts.
    """
    mesh, rules = _CURRENT["mesh"], _CURRENT["rules"]
    if mesh is None or rules is None:
        return x
    spec = spec_for(tuple(logical_axes), rules, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
