"""Distribution: logical-axis sharding, mesh registry, gradient compression."""
from .sharding import (FSDP_ARCHS, batch_axes, constrain, current_mesh,
                       current_rules, rules_for, shardings_for, spec_for,
                       use_mesh_rules, zero1_shardings)
from .compression import compressed_psum_pod

__all__ = ["FSDP_ARCHS", "batch_axes", "constrain", "current_mesh",
           "current_rules", "rules_for", "shardings_for", "spec_for",
           "use_mesh_rules", "zero1_shardings", "compressed_psum_pod"]
