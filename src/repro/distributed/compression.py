"""Gradient compression: int8 ring all-reduce over the pod axis.

Inter-pod links are the slowest tier (DCI < ICI), so the pure-DP gradient
all-reduce across pods is the natural compression target.  We quantize each
block to int8 with a per-tensor f32 scale (stochastic rounding to keep the
estimator unbiased), run a ring exchange over the pod axis inside
``shard_map``, and dequantize.  4x fewer bytes on the slow links for <1%
gradient RMS error (tests/test_distributed.py checks the numerics).

The public entry is :func:`compressed_psum_pod`, used by the train-step
builder when ``grad_compression="int8"``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def _quantize(x: jax.Array, key: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    # stochastic rounding: unbiased under expectation
    noise = jax.random.uniform(key, y.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ring_allreduce_int8(x: jax.Array, key: jax.Array, axis: str
                         ) -> jax.Array:
    """All-reduce of f32 ``x`` over ``axis`` moving int8 on the wire."""
    # jax.lax.axis_size is not available on every supported jax; psum of 1
    # over the axis is the portable spelling of the same number.
    n = int(jax.lax.psum(1, axis))
    idx = jax.lax.axis_index(axis)
    q, scale = _quantize(x, jax.random.fold_in(key, idx))
    acc = _dequantize(q, scale)           # own (quantized) contribution
    perm = [(i, (i + 1) % n) for i in range(n)]
    cur_q, cur_s = q, scale
    for _ in range(n - 1):
        cur_q = jax.lax.ppermute(cur_q, axis, perm)
        cur_s = jax.lax.ppermute(cur_s, axis, perm)
        acc = acc + _dequantize(cur_q, cur_s)
    return acc


def compressed_psum_pod(grads: PyTree, mesh: Mesh, key: jax.Array) -> PyTree:
    """psum over the 'pod' axis with int8 wire format.

    Input grads must already be summed within each pod (the usual GSPMD
    all-reduce over 'data'/'model'); this handles only the inter-pod hop.
    Leaves keep their sharding over the other axes (``P`` below only names
    the pod axis; shard_map treats the rest as replicated-per-shard).
    """
    if "pod" not in mesh.axis_names:
        return grads

    def one(leaf_key, g):
        spec = P(*(("pod",) + (None,) * (g.ndim - 1))) if g.ndim else P()
        # grads are replicated over pod on entry -> use P() in/out with the
        # reduction done on fully-addressable shards
        fn = shard_map(
            functools.partial(_ring_allreduce_int8, axis="pod"),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(),
            check_rep=False)
        return fn(g.astype(jnp.float32), leaf_key).astype(g.dtype)

    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [one(k, g) for k, g in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)
